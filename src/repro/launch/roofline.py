"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds-per-step *per chip*:

    compute    = HLO_FLOPs / peak_FLOPs            (cost_analysis flops)
    memory     = HLO_bytes / HBM_bw                (cost_analysis bytes)
    collective = collective_bytes / link_bw        (parsed from HLO text)

``cost_analysis`` on the SPMD-partitioned module reports **per-device**
numbers, so no further division by chip count is needed (the spec's
"/ chips" with global numerators is the same quantity).

collective_bytes sums the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op in the compiled module
(per spec).  Hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  f32[16,1024]{1,0}   bf16[8]   pred[]
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_COLL_LINE_RE = re.compile(
    r"=\s*(\(?)([a-z0-9]+\[[0-9,]*\][^)\s]*(?:,\s*[a-z0-9]+\[[0-9,]*\][^)\s]*)*)\)?\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Per-device collective bytes from compiled HLO text.

    Compiled modules don't annotate operand shapes inline, so bytes come
    from the *result* shape, adjusted per op so the number approximates the
    operand-bytes convention of the spec: all-gather result = operand ×
    group (we report the result — the bytes a device materializes over the
    ring); reduce-scatter result = operand / group (× group to recover
    operand bytes); all-reduce / all-to-all / collective-permute results
    equal their operands.  ``-done`` halves of async pairs are skipped to
    avoid double counting."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped or "-done.clone(" in stripped:
            continue
        m = _COLL_LINE_RE.search(stripped)
        if not m:
            continue
        shapes = _SHAPE_RE.findall(m.group(2))
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        op = m.group(3)
        if op == "reduce-scatter":
            g = _GROUPS_RE.search(stripped)
            if g:
                nbytes *= int(g.group(2))
        out[op] += nbytes
        out["count"] += 1
    return out


# ---------------------------------------------------------------------------
# Scan-aware HLO accounting.
#
# `lax.scan` lowers to a While whose body appears ONCE in the module, so a
# naive static walk undercounts per-layer collectives/bytes by ~n_groups.
# This walker segments the module into computations, finds While trip counts
# from their condition computations, and multiplies each computation's
# contribution by the product of enclosing trip counts.  Fusion-internal
# instructions don't touch HBM and are excluded from the bytes proxy.

_NAME_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+).*?body=%?([\w\.\-]+)",
                            re.S)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_RESULT_RE = re.compile(r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(\(?[^=]*?)\s*"
                        r"([a-z][a-z0-9\-]*)\(")


def _parse_computations(hlo_text: str):
    """Split the module into computations.  Header lines end with '{' and
    contain a '->' return annotation (params may nest tuples, so no paren
    matching)."""
    comps: dict[str, list[str]] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and ") -> " in stripped:
            m = _NAME_RE.match(stripped)
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps


def scan_aware_analysis(hlo_text: str) -> dict:
    """Returns {"coll": {kind: bytes}, "coll_count": int,
    "result_bytes": float} with While-trip multipliers applied."""
    comps = _parse_computations(hlo_text)
    # fusion-internal computations: excluded from byte accounting
    fusion_comps: set[str] = set()
    # while wiring: body/cond comp -> (trip count, caller comp)
    called_by: dict[str, tuple[int, str]] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " fusion(" in line or line.strip().startswith("fusion("):
                for fc in _CALLS_RE.findall(line):
                    fusion_comps.add(fc)
            if " while(" in line:
                wm = _WHILE_ATTR_RE.search(line)
                if not wm:
                    continue
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(line)
                if tm:
                    trips = int(tm.group(1))
                else:
                    consts = [int(c) for c in _CONST_RE.findall(
                        "\n".join(comps.get(cond, ())))]
                    big = [c for c in consts if 1 < c < 1_000_000]
                    trips = max(big) if big else 1
                called_by[body] = (trips, cname)
                called_by[cond] = (trips, cname)
                fusion_comps.discard(body)

    mult_memo: dict[str, int] = {}

    def multiplier(cname: str) -> int:
        if cname in mult_memo:
            return mult_memo[cname]
        m = 1
        if cname in called_by:
            trips, caller = called_by[cname]
            mult_memo[cname] = 1  # break cycles
            m = trips * multiplier(caller)
        mult_memo[cname] = m
        return m

    # fusions containing a dynamic-update-slice act as loop accumulators
    # (the DUS may feed a ROOT tuple, so scan the whole body)
    dus_fusions: set[str] = set()
    for fc in fusion_comps:
        for line in comps.get(fc, ()):
            if "dynamic-update-slice(" in line:
                dus_fusions.add(fc)
                break

    coll = {k: 0 for k in _COLLECTIVES}
    count = 0
    result_bytes = 0.0
    for cname, lines in comps.items():
        mul = multiplier(cname)
        own_trips = called_by.get(cname, (1, None))[0]
        in_fusion = cname in fusion_comps
        for line in lines:
            s = line.strip()
            if "-done(" in s:
                continue
            rm = _RESULT_RE.match(s)
            if not rm:
                continue
            shapes = _SHAPE_RE.findall(rm.group(1))
            nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
            op = rm.group(2)
            base = None
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    base = c
                    break
            if base is not None:
                b = nbytes
                if base == "reduce-scatter":
                    g = _GROUPS_RE.search(s)
                    if g:
                        b *= int(g.group(2))
                coll[base] += b * mul
                count += 1
            if not in_fusion and op not in ("parameter", "constant",
                                            "get-tuple-element", "tuple",
                                            "bitcast", "while"):
                eff = nbytes
                is_accum = op in ("dynamic-update-slice", "copy")
                if op == "fusion":
                    cm = _CALLS_RE.search(s)
                    if cm and cm.group(1) in dus_fusions:
                        is_accum = True
                if is_accum:
                    # loop-carried accumulators: the result shape is the
                    # whole buffer but each iteration writes 1/trips of it
                    eff = nbytes / max(own_trips, 1)
                result_bytes += eff * mul
    return {"coll": coll, "coll_count": count,
            "result_bytes": result_bytes * 2.0}   # write + typical re-read


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    compute_s: float
    memory_s: float
    collective_s: float
    # raw spec-literal values (static HLO walk / cost_analysis):
    raw_flops: float = 0.0
    raw_hbm_bytes: float = 0.0
    raw_coll_bytes: float = 0.0

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Naive no-overlap upper bound."""
        return self.compute_s + self.memory_s + self.collective_s

    @property
    def bound_s(self) -> float:
        """Perfect-overlap lower bound = max term."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self, model_flops_per_chip: float) -> float:
        """useful-FLOPs time / achievable step time (perfect overlap).

        The achievable step time is max(terms, ideal): XLA:CPU cost_analysis
        does not count FLOPs inside fused computations, so the raw compute
        term can fall below the 6ND ideal — the ideal is the physical floor,
        which also caps the fraction at 1."""
        ideal = model_flops_per_chip / PEAK_FLOPS
        denom = max(self.bound_s, ideal)
        return ideal / denom if denom else 0.0

    def to_dict(self):
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes,
            "coll_breakdown": self.coll_breakdown,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "raw_flops": self.raw_flops, "raw_hbm_bytes": self.raw_hbm_bytes,
            "raw_coll_bytes": self.raw_coll_bytes,
        }


def analyze(compiled, hlo_text: str | None = None,
            body_flops_correction: float = 0.0) -> RooflineTerms:
    """Scan-aware roofline terms.

    * memory / collective: from the While-trip-aware HLO walk (the static
      spec-literal values are kept as raw_*).
    * compute: cost_analysis FLOPs count scan bodies once and skip fused
      ops on CPU; ``body_flops_correction`` adds the analytic
      (n_groups − 1) × per-group FLOPs so depth is represented.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):           # older API returns per-device list
        ca = ca[0]
    raw_flops = float(ca.get("flops", 0.0))
    raw_bytes = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    raw_coll = collective_bytes(text)
    raw_coll_total = float(sum(v for k, v in raw_coll.items() if k != "count"))
    sa = scan_aware_analysis(text)
    coll = dict(sa["coll"])
    coll["count"] = sa["coll_count"]
    total_coll = float(sum(v for k, v in coll.items() if k != "count"))
    nbytes = max(sa["result_bytes"], raw_bytes)
    flops = raw_flops + body_flops_correction
    return RooflineTerms(
        flops=flops, hbm_bytes=nbytes, coll_bytes=total_coll,
        coll_breakdown=coll,
        compute_s=flops / PEAK_FLOPS,
        memory_s=nbytes / HBM_BW,
        collective_s=total_coll / LINK_BW,
        raw_flops=raw_flops, raw_hbm_bytes=raw_bytes,
        raw_coll_bytes=raw_coll_total,
    )


def model_flops_per_step(arch, shape, n_chips: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE), per chip.

    D = tokens processed per step.  For decode shapes D = global_batch new
    tokens (the KV-cache read is memory, not FLOPs)."""
    total, active = arch.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq
        factor = 6
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq
        factor = 2
    else:
        tokens = shape.global_batch
        factor = 2
    return factor * active * tokens / n_chips
