"""Production mesh construction.

A FUNCTION (not module-level constant) so importing never touches jax device
state.  Single pod: (data=16, model=16) = 256 chips (v5e pod).  Multi-pod:
(pod=2, data=16, model=16) = 512 chips; the ``pod`` axis is pure DP over DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host has (tests / smoke runs)."""
    n = len(jax.devices())
    if model > 1 and n % model == 0:
        return jax.make_mesh((n // model, model), ("data", "model"))
    return jax.make_mesh((n, 1), ("data", "model"))
