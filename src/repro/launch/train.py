"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
        --steps 200 --batch 8 --seq 128

On this CPU container use ``--smoke`` (reduced config); on a pod the full
config + production mesh apply unchanged.  The input pipeline is the LaFP
lazy engine (repro.data.pipeline) — the paper's technique feeding the
trainer.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from ..configs import get_config
from ..data.pipeline import (PipelineConfig, PrefetchIterator, TokenPipeline,
                             synthetic_token_source)
from ..distributed.sharding import param_shardings
from ..models.layers import init_from_spec
from ..models.transformer import model_spec
from ..train.loop import LoopConfig, Trainer
from ..train.optim import OptimConfig, init_opt_state
from ..train.train_step import TrainConfig, make_train_step
from .mesh import make_host_mesh


def build_state(arch, seed: int = 0, mesh=None):
    spec = model_spec(arch)
    params = init_from_spec(spec, jax.random.PRNGKey(seed))
    if mesh is not None:
        sh = param_shardings(spec, mesh)
        params = jax.tree.map(jax.device_put, params, sh)
    return {"params": params, "opt": init_opt_state(params)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--docs", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--loss-mode", default="sharded_vocab")
    args = ap.parse_args(argv)

    arch = get_config(args.arch)
    if args.smoke:
        arch = arch.smoke()
    mesh = make_host_mesh()

    tcfg = TrainConfig(
        optim=OptimConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                          total_steps=args.steps),
        microbatches=args.microbatches, loss_mode=args.loss_mode)
    train_step = jax.jit(make_train_step(arch, tcfg), donate_argnums=(0,))

    source = synthetic_token_source(args.docs, args.seq, arch.vocab)
    pipe = TokenPipeline(source, PipelineConfig(batch=args.batch,
                                                seq=args.seq))
    data = PrefetchIterator(iter(pipe), depth=2)

    state = build_state(arch, mesh=mesh)
    trainer = Trainer(train_step, state, data,
                      LoopConfig(total_steps=args.steps,
                                 ckpt_every=args.ckpt_every,
                                 ckpt_dir=args.ckpt_dir),
                      pipeline_state=pipe.state)
    if args.resume:
        trainer.try_resume()
    summary = trainer.run()
    print({"summary": summary}, flush=True)
    return summary


if __name__ == "__main__":
    main()
