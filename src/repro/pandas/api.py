"""Pandas-shaped top-level entry points for the `repro.pandas` facade:
``DataFrame`` / ``Series`` constructors and the module functions ``concat``,
``merge``, ``to_datetime``, ``isna``.

Everything returns lazy values (LazyFrame / LazyColumn) over in-memory
partitioned sources; string data is dictionary-encoded on ingest (paper
§3.6), datetime64 data becomes int64 epoch seconds (the engine's device
representation)."""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import numpy as np

from repro.core import expr as E
from repro.core import graph as G
from repro.core.lazyframe import LazyColumn, LazyFrame
from repro.core.source import InMemorySource, encode_strings

from .fallback import record_fallback
from .io import _parse_datetimes


def _ingest_column(values) -> tuple[np.ndarray, list | None, bool]:
    """array-like → (array, vocab | None, is_datetime)."""
    arr = np.asarray(values)
    if arr.dtype.kind == "M":                         # datetime64
        return arr.astype("datetime64[s]").astype(np.int64), None, True
    if arr.dtype.kind in "OUS":
        vals = [str(v) for v in arr.ravel()]
        if vals and all(len(v) >= 10 and v[4:5] == "-" and v[7:8] == "-"
                        for v in vals):
            try:
                return _parse_datetimes(vals), None, True
            except ValueError:
                pass          # ISO-*looking* strings, not actual datetimes
        codes, vocab = encode_strings(vals)
        return codes, vocab, False
    return arr, None, False


def _ingest(data: Mapping[str, Any], name: str = "dataframe",
            partition_rows: int = 1 << 16) -> LazyFrame:
    arrays, dicts, datetimes = {}, {}, []
    for col, values in data.items():
        arr, vocab, is_dt = _ingest_column(values)
        arrays[col] = arr
        if vocab is not None:
            dicts[col] = vocab
        if is_dt:
            datetimes.append(col)
    src = InMemorySource(arrays, partition_rows, dicts, datetimes, name)
    return LazyFrame(G.Scan(src), source_vocab=src.dicts)


def DataFrame(data=None, columns: Sequence[str] | None = None,
              index=None) -> LazyFrame:  # noqa: N802 — pandas name
    """``pd.DataFrame(...)`` — accepts a dict of columns, a list of row
    dicts, a 2-D array (+ ``columns``), or an existing LazyFrame (copy).
    ``index`` is accepted for signature compatibility and ignored (the
    engine is positional, like the paper's)."""
    if isinstance(data, LazyFrame):
        return data.copy()
    if isinstance(data, Mapping):
        if not data:
            raise ValueError("repro.pandas.DataFrame needs at least one column")
        return _ingest(data)
    if isinstance(data, np.ndarray) and data.ndim == 2:
        names = list(columns) if columns is not None else \
            [f"c{i}" for i in range(data.shape[1])]
        return _ingest({n: data[:, i] for i, n in enumerate(names)})
    if isinstance(data, (list, tuple)) and data and isinstance(data[0], Mapping):
        names = list(columns) if columns is not None else list(data[0])
        return _ingest({n: [row.get(n) for row in data] for n in names})
    raise TypeError(f"cannot construct DataFrame from {type(data)}")


def Series(data, name: str | None = None) -> LazyColumn:  # noqa: N802
    """``pd.Series(...)`` — a single named lazy column (backed by a
    one-column in-memory frame)."""
    if isinstance(data, LazyColumn):
        return data
    name = name if name is not None else "value"
    return _ingest({name: data}, name=f"series:{name}")[name]


def concat(objs: Sequence[LazyFrame], axis: int = 0,
           ignore_index: bool = True) -> LazyFrame:
    """Row-wise concat.  Stays lazy (a Concat node) when the frames'
    dictionary vocabularies agree; mismatched vocabs force the measured
    fallback path: materialize, decode, re-encode, re-wrap."""
    objs = list(objs)
    if axis != 0:
        raise NotImplementedError("concat(axis=1) is not supported")
    if not objs:
        raise ValueError("No objects to concatenate")
    if len(objs) == 1:
        return objs[0].copy()
    vocab: dict[str, list] = {}
    compatible = True
    for f in objs:
        for k, v in f._vocab.items():
            if k in vocab and vocab[k] != v:
                compatible = False
            vocab.setdefault(k, v)
    if compatible:
        return LazyFrame(G.Concat([f._node for f in objs]), source_vocab=vocab)
    # fallback: re-encode against a merged vocabulary.  Column set is the
    # union (pandas outer concat): numeric gaps NaN-fill; string gaps get ""
    # (dict-encoded columns can't carry NaN).
    mats = [f.compute(force_reason="fallback:concat") for f in objs]
    rows = sum(m.rows() for m in mats)
    record_fallback("concat", (rows, len(mats[0].columns)),
                    "vocab-mismatch-reencode")
    names: list[str] = []
    for m in mats:
        for n in m.columns:
            if n not in names:
                names.append(n)
    merged: dict[str, Any] = {}
    for n in names:
        is_str = any(n in m.vocab for m in mats)
        missing = any(n not in m.columns for m in mats)
        parts = []
        for m in mats:
            if n not in m.columns:
                parts.append([""] * m.rows() if is_str
                             else np.full(m.rows(), np.nan))
            elif n in m.vocab:
                parts.append([m.vocab[n][c] for c in np.asarray(m.columns[n])])
            else:
                arr = np.asarray(m.columns[n])
                parts.append(arr.astype(np.float64) if missing else arr)
        if is_str:
            merged[n] = np.concatenate([np.asarray(p, dtype=object)
                                        for p in parts])
        else:
            merged[n] = np.concatenate(parts)
    return _ingest(merged, name="concat")


def merge(left: LazyFrame, right: LazyFrame, on, how: str = "inner",
          suffixes=("_x", "_y")) -> LazyFrame:
    return left.merge(right, on=on, how=how, suffixes=suffixes)


def to_datetime(arg, format: str | None = None):  # noqa: A002
    """Convert to the engine's datetime representation (int64 epoch
    seconds).  Lazy columns: int columns pass through; dict-encoded string
    columns are parsed once on the vocabulary and mapped per row via a
    lazy lookup-table UDF."""
    if isinstance(arg, LazyColumn):
        try:
            vocab = arg.frame._vocab_for(arg.expr)
        except KeyError:
            return arg                     # already numeric epoch seconds
        lut = _parse_datetimes(vocab)
        record_fallback("to_datetime", (len(vocab),), "vocab-parse-lut")
        fn = lambda codes: lut[np.asarray(codes)]  # noqa: E731
        return LazyColumn(arg.frame,
                          E.UDF(fn, (arg.expr,), name="to_datetime"))
    if isinstance(arg, str):
        return int(_parse_datetimes([arg])[0])
    return Series(_parse_datetimes([str(v) for v in np.asarray(arg).ravel()]),
                  name="datetime")


def isna(obj):
    """``pd.isna`` — lazy elementwise NaN test for columns, eager for
    arrays/scalars."""
    if isinstance(obj, LazyColumn):
        return obj.isna()
    arr = np.asarray(obj)
    if arr.ndim == 0:
        return bool(np.isnan(arr)) if arr.dtype.kind == "f" else obj is None
    if arr.dtype.kind == "f":
        return np.isnan(arr)
    return np.zeros(arr.shape, bool)


def notna(obj):
    res = isna(obj)
    if isinstance(res, LazyColumn):
        return ~res
    return ~np.asarray(res) if isinstance(res, np.ndarray) else not res
