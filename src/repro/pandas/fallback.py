"""Graceful fallback protocol for the `repro.pandas` facade.

Any DataFrame / Series / GroupBy method or accessor field the lazy layer
does not implement natively is served from a registered numpy-level kernel
table instead of raising ``AttributeError``:

* **aligned elementwise ops** (clip, abs, round, dt.dayofyear, str.len, …)
  stay lazy — the kernel is wrapped as a UDF expression node and executes
  per partition at force time (safe: value depends only on the row);
* **everything else** (nlargest, value_counts, quantile, groupby.std, …)
  *materializes its inputs*, runs the kernel eagerly on host numpy, and
  re-wraps the result as a new lazy in-memory source;
* ops with **no registered kernel** raise ``AttributeError`` *after*
  recording the gap.

Every event is appended to ``ctx.fallback_trace`` as a :class:`FallbackEvent`
(op name, input shape, force reason, status) — API coverage is measured
(`benchmarks/run.py api_coverage`), not asserted.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import expr as E
from repro.core.context import get_context
from repro.core.source import InMemorySource, encode_strings


@dataclasses.dataclass
class FallbackEvent:
    op: str                      # e.g. "DataFrame.nlargest", "Series.dt.quarter"
    shape: tuple | None          # input shape (rows, cols) when materialized
    reason: str                  # why/how the fallback fired
    status: str = "fallback"     # "fallback" (served) | "failed" (no kernel)

    def __str__(self):
        shape = "x".join(map(str, self.shape)) if self.shape else "?"
        return f"{self.status}: {self.op} [{shape}] {self.reason}"


def record_fallback(op: str, shape: tuple | None, reason: str,
                    status: str = "fallback") -> FallbackEvent:
    ev = FallbackEvent(op, shape, reason, status)
    ctx = get_context()
    ctx.fallback_trace.append(ev)
    # telemetry (repro.obs): count served/failed fallbacks, and — when a
    # profile is attached — record an instant "fallback" event span
    metrics = getattr(ctx, "metrics", None)
    if metrics is not None:
        metrics.inc("fallback.failed" if status == "failed"
                    else "fallback.served")
    tracer = getattr(ctx, "tracer", None)
    if tracer is not None and tracer.enabled:
        tracer.event("fallback", op=op, status=status, reason=reason,
                     **({"shape": shape} if shape else {}))
    return ev


def _unsupported(op: str):
    record_fallback(op, None, "no-registered-kernel", status="failed")
    raise AttributeError(
        f"{op} has no native lazy implementation and no fallback kernel; "
        "the gap was recorded in get_context().fallback_trace")


# ---------------------------------------------------------------------------
# Re-wrapping kernel outputs as lazy values


def _frame_from(arrays: dict, dicts: dict | None, op: str):
    from repro.core.lazyframe import LazyFrame
    from repro.core import graph as G
    src = InMemorySource({k: np.asarray(v) for k, v in arrays.items()},
                         dicts=dicts, name=f"fallback:{op}")
    return LazyFrame(G.Scan(src), source_vocab=src.dicts)


def _series_from(arr: np.ndarray, name: str, op: str, vocab: list | None = None):
    dicts = {name: vocab} if vocab is not None else None
    return _frame_from({name: arr}, dicts, op)[name]


def _rewrap(value, vocab: dict, op: str, series_name: str = "value"):
    """Kernel output → lazy value: dict → LazyFrame backed by a fresh
    in-memory source, ndarray → single-column Series, numpy scalar →
    python scalar; anything else passes through raw."""
    if isinstance(value, dict):
        dicts = {k: vocab[k] for k in value if k in (vocab or {})}
        return _frame_from(value, dicts, op)
    if isinstance(value, np.ndarray):
        return _series_from(value, series_name, op)
    if isinstance(value, np.generic):
        return value.item()
    return value


def _materialize_frame(frame, op: str):
    res = frame.compute(force_reason=f"fallback:{op}")
    cols = {k: np.asarray(v) for k, v in res.columns.items()}
    rows = res.rows()
    return cols, res.vocab, (rows, len(cols))


# ---------------------------------------------------------------------------
# DataFrame-level kernels: (cols, vocab, *args, **kwargs) -> dict | scalar |
# raw python object.  ``cols`` is a dict of host numpy arrays.


def _float_cols(cols, subset=None):
    names = subset if subset is not None else list(cols)
    return [n for n in names if cols[n].dtype.kind == "f"]


def _take(cols, idx):
    return {k: v[idx] for k, v in cols.items()}


def _k_dropna(cols, vocab, subset=None):
    mask = None
    for n in _float_cols(cols, subset):
        m = ~np.isnan(cols[n])
        mask = m if mask is None else (mask & m)
    if mask is None:
        return dict(cols)
    return _take(cols, np.flatnonzero(mask))


def _k_tail(cols, vocab, n=5):
    return {k: v[-n:] if n else v[:0] for k, v in cols.items()}


def _drop_nan_idx(arr):
    """Index of non-NaN entries (pandas nlargest/nsmallest drop NaN)."""
    if arr.dtype.kind == "f":
        return np.flatnonzero(~np.isnan(arr))
    return np.arange(len(arr))


def _k_nlargest(cols, vocab, n, columns):
    key = columns if isinstance(columns, str) else columns[0]
    valid = _drop_nan_idx(cols[key])
    idx = valid[np.argsort(cols[key][valid], kind="stable")[::-1][:n]]
    return _take(cols, idx)


def _k_nsmallest(cols, vocab, n, columns):
    key = columns if isinstance(columns, str) else columns[0]
    valid = _drop_nan_idx(cols[key])
    idx = valid[np.argsort(cols[key][valid], kind="stable")[:n]]
    return _take(cols, idx)


def _k_sample(cols, vocab, n=None, frac=None, random_state=0):
    rows = len(next(iter(cols.values()))) if cols else 0
    if n is None:
        n = int(round(rows * (frac if frac is not None else 1.0)))
    rng = np.random.default_rng(random_state)
    idx = rng.choice(rows, size=min(n, rows), replace=False)
    return _take(cols, idx)


def _k_identity(cols, vocab, *args, **kwargs):
    return dict(cols)


def _frame_stat(fn):
    def kern(cols, vocab, **kwargs):
        out = {}
        for name, arr in cols.items():
            if arr.dtype.kind in "if" and name not in (vocab or {}):
                out[name] = np.asarray([fn(arr, **kwargs)])
        return out
    return kern


def _k_query(cols, vocab, expr: str):
    # pandas.query fallback: textual predicate evaluated against the
    # materialized columns.  Word operators become bitwise ones with each
    # clause parenthesized — '&' binds tighter than comparisons, so
    # 'a == 1 and b == 2' must become '(a == 1) & (b == 2)'.
    txt = "(" + expr.replace(" and ", ") & (").replace(" or ", ") | (") + ")"
    txt = txt.replace("not ", "~")
    mask = eval(txt, {"__builtins__": {}}, dict(cols))  # noqa: S307
    return _take(cols, np.flatnonzero(np.asarray(mask)))


def _k_iterrows(cols, vocab):
    names = list(cols)
    rows = len(cols[names[0]]) if names else 0
    def gen():
        for i in range(rows):
            yield i, {n: cols[n][i] for n in names}
    return gen()


def _q(arr, q=0.5, **kw):
    return np.nanquantile(arr, q)


# skipna=True statistics (pandas default): NaN-aware for float inputs
def _nanmedian(a):
    return np.nanmedian(a)


def _nanstd(a, ddof=1):
    return np.nanstd(a, ddof=ddof)


def _nanvar(a, ddof=1):
    return np.nanvar(a, ddof=ddof)


def _k_drop(cols, vocab, columns):
    columns = [columns] if isinstance(columns, str) else list(columns)
    return {k: v for k, v in cols.items() if k not in columns}


FRAME_KERNELS = {
    "drop": _k_drop,
    "dropna": _k_dropna,
    "tail": _k_tail,
    "nlargest": _k_nlargest,
    "nsmallest": _k_nsmallest,
    "sample": _k_sample,
    "reset_index": _k_identity,
    "sort_index": _k_identity,
    "query": _k_query,
    "iterrows": _k_iterrows,
    "median": _frame_stat(_nanmedian),
    "std": _frame_stat(_nanstd),
    "var": _frame_stat(_nanvar),
    "quantile": _frame_stat(_q),
}


def frame_fallback(frame, name: str):
    kern = FRAME_KERNELS.get(name)
    if kern is None:
        _unsupported(f"DataFrame.{name}")

    def bound(*args, **kwargs):
        cols, vocab, shape = _materialize_frame(frame, name)
        record_fallback(f"DataFrame.{name}", shape, "materialize-input")
        return _rewrap(kern(cols, vocab, *args, **kwargs), vocab, name)

    bound.__name__ = name
    bound.__qualname__ = f"LazyFrame.{name} (fallback)"
    return bound


# ---------------------------------------------------------------------------
# Series-level kernels.
#
# SERIES_ELEMENTWISE: aligned, row-local — wrapped as a lazy UDF node
# (correct under any partitioning).  SERIES_KERNELS: order/whole-column
# semantics — materialize the column eagerly.


SERIES_ELEMENTWISE = {
    "clip": lambda a, lower=None, upper=None: np.clip(a, lower, upper),
    "abs": lambda a: np.abs(a),
    "round": lambda a, decimals=0: np.round(a, decimals),
    "isna": lambda a: np.isnan(a) if a.dtype.kind == "f" else np.zeros(len(a), bool),
    "isnull": lambda a: np.isnan(a) if a.dtype.kind == "f" else np.zeros(len(a), bool),
    "notna": lambda a: ~np.isnan(a) if a.dtype.kind == "f" else np.ones(len(a), bool),
    "between": lambda a, left, right: (a >= left) & (a <= right),
    "floor": lambda a: np.floor(a),
    "sqrt": lambda a: np.sqrt(a),
}


def _s_unique(arr):
    _, first = np.unique(arr, return_index=True)
    return arr[np.sort(first)]          # first-occurrence order (pandas)


def _s_value_counts(arr):
    uniq, counts = np.unique(arr, return_counts=True)
    order = np.argsort(counts, kind="stable")[::-1]
    return {"value": uniq[order], "count": counts[order]}


SERIES_KERNELS = {
    # median graduated to a native Reduce node (repro.core.physical.reduce)
    "std": lambda arr, ddof=1: np.nanstd(arr, ddof=ddof),
    "var": lambda arr, ddof=1: np.nanvar(arr, ddof=ddof),
    "quantile": lambda arr, q=0.5: np.nanquantile(arr, q),
    "unique": _s_unique,
    "value_counts": _s_value_counts,
    "nlargest": lambda arr, n=5: arr[np.argsort(arr, kind="stable")[::-1][:n]],
    "nsmallest": lambda arr, n=5: arr[np.argsort(arr, kind="stable")[:n]],
    # order-dependent length-preserving ops: correct only on the whole
    # column, so they materialize rather than wrap as a per-partition UDF
    "cumsum": lambda arr: np.cumsum(arr),
    "cummax": lambda arr: np.maximum.accumulate(arr),
    "cummin": lambda arr: np.minimum.accumulate(arr),
    "diff": lambda arr: np.concatenate([[np.nan], np.diff(arr.astype(np.float64))]),
    "shift": lambda arr, periods=1: _s_shift(arr, periods),
    "rank": lambda arr: _s_rank(arr),
    "mode": lambda arr: _s_value_counts(arr)["value"][:1],
}


def _s_shift(arr, periods=1):
    arr = arr.astype(np.float64)
    if periods == 0:
        return arr
    if periods > 0:
        return np.concatenate([np.full(periods, np.nan), arr[:-periods]])
    return np.concatenate([arr[-periods:], np.full(-periods, np.nan)])


def _s_rank(arr):
    """pandas default rank: method='average', NaN stays NaN."""
    arr = np.asarray(arr)
    out = np.full(len(arr), np.nan)
    valid = ~np.isnan(arr) if arr.dtype.kind == "f" else np.ones(len(arr), bool)
    vals = arr[valid]
    if not len(vals):
        return out
    order = np.argsort(vals, kind="stable")
    ordinal = np.empty(len(vals))
    ordinal[order] = np.arange(1, len(vals) + 1)
    uniq, inv = np.unique(vals, return_inverse=True)
    avg = np.bincount(inv, weights=ordinal) / np.bincount(inv)
    out[valid] = avg[inv]
    return out


def _series_name(col) -> str:
    return col.expr.name if isinstance(col.expr, E.Col) else "value"


def _materialize_series(col, op: str) -> np.ndarray:
    return np.asarray(col.compute(force_reason=f"fallback:{op}"))


def series_fallback(col, name: str):
    from repro.core.lazyframe import LazyColumn

    if name in SERIES_ELEMENTWISE:
        kern = SERIES_ELEMENTWISE[name]

        def wrapped(*args, **kwargs):
            record_fallback(f"Series.{name}", None, "wrapped-udf")
            fn = lambda a: kern(np.asarray(a), *args, **kwargs)  # noqa: E731
            return LazyColumn(col.frame,
                              E.UDF(fn, (col.expr,), name=f"fallback.{name}"))

        wrapped.__name__ = name
        return wrapped

    kern = SERIES_KERNELS.get(name)
    if kern is None:
        _unsupported(f"Series.{name}")

    def bound(*args, **kwargs):
        arr = _materialize_series(col, name)
        record_fallback(f"Series.{name}", (len(arr),), "materialize-input")
        out = kern(arr, *args, **kwargs)
        try:
            svocab = col.frame._vocab_for(col.expr)
        except KeyError:
            svocab = None
        if svocab is not None:
            # dict-encoded column: results carrying codes keep their vocab
            if isinstance(out, dict) and "value" in out:
                return _frame_from(out, {"value": svocab}, name)
            if isinstance(out, np.ndarray) and out.dtype.kind in "iu":
                return _series_from(out, _series_name(col), name, vocab=svocab)
        return _rewrap(out, {}, name, series_name=_series_name(col))

    bound.__name__ = name
    return bound


# ---------------------------------------------------------------------------
# GroupBy fallback: aggregations the GroupByAgg node doesn't know
# (median/std/var/first/last/quantile) via a host numpy group-apply.


GROUPBY_REDUCERS = {
    "median": lambda g: np.nanmedian(g),
    "std": lambda g: np.nanstd(g, ddof=1),
    "var": lambda g: np.nanvar(g, ddof=1),
    "first": lambda g: g[0],
    "last": lambda g: g[-1],
    "quantile": lambda g, q=0.5: np.nanquantile(g, q),
}


def _groupby_apply(cols, keys, targets, reducer, *args, **kwargs):
    keyarrs = [np.asarray(cols[k]) for k in keys]
    rows = len(keyarrs[0])
    if rows == 0:
        out = {k: ka[:0] for k, ka in zip(keys, keyarrs)}
        for t in targets:
            out[t] = np.asarray(cols[t])[:0].astype(np.float64)
        return out
    combined = np.zeros(rows, np.int64)
    for ka in keyarrs:
        uniq, inv = np.unique(ka, return_inverse=True)
        combined = combined * max(len(uniq), 1) + inv
    _, ginv = np.unique(combined, return_inverse=True)
    order = np.argsort(ginv, kind="stable")
    bounds = np.flatnonzero(np.diff(ginv[order])) + 1
    first_idx = order[np.concatenate([[0], bounds])] if rows else order[:0]
    out = {k: np.asarray(cols[k])[first_idx] for k in keys}
    for t in targets:
        groups = np.split(np.asarray(cols[t])[order], bounds)
        out[t] = np.asarray([reducer(g, *args, **kwargs) for g in groups])
    return out


def groupby_fallback(gb, col: str | None, name: str):
    reducer = GROUPBY_REDUCERS.get(name)
    if reducer is None:
        _unsupported(f"GroupBy.{name}")

    def bound(*args, **kwargs):
        cols, vocab, shape = _materialize_frame(gb.frame, f"groupby.{name}")
        record_fallback(f"GroupBy.{name}", shape, "materialize-input")
        if col is not None:
            targets = [col]
        else:
            targets = [n for n in cols
                       if n not in gb.keys and cols[n].dtype.kind in "if"
                       and n not in (vocab or {})]
        out = _groupby_apply(cols, gb.keys, targets, reducer, *args, **kwargs)
        return _rewrap(out, vocab, f"groupby.{name}")

    bound.__name__ = name
    return bound


# ---------------------------------------------------------------------------
# .dt accessor fallback fields (aligned elementwise → lazy UDF wrap).


def _dt_civil(ts):
    return E._civil_from_days(np.asarray(ts) // 86400)


def _dt_dayofyear(ts):
    d64 = np.asarray(ts).astype("int64").astype("datetime64[s]")
    day = d64.astype("datetime64[D]")
    jan1 = d64.astype("datetime64[Y]").astype("datetime64[D]")
    return (day - jan1).astype(np.int64) + 1


def _dt_days_in_month(ts):
    m = np.asarray(ts).astype("int64").astype("datetime64[s]").astype("datetime64[M]")
    return ((m + 1).astype("datetime64[D]") - m.astype("datetime64[D]")).astype(np.int64)


DT_KERNELS = {
    "weekday": lambda ts: ((np.asarray(ts) // 86400) + 3) % 7,
    "dayofyear": _dt_dayofyear,
    # quarter graduated to a native DtField expr (repro.core.expr._DT_FIELDS)
    "days_in_month": _dt_days_in_month,
    "is_month_start": lambda ts: _dt_civil(ts)[2] == 1,
    "is_month_end": lambda ts: _dt_civil(ts)[2] == _dt_days_in_month(ts),
}


def dt_fallback(col, field: str):
    from repro.core.lazyframe import LazyColumn
    kern = DT_KERNELS.get(field)
    if kern is None:
        _unsupported(f"Series.dt.{field}")
    record_fallback(f"Series.dt.{field}", None, "wrapped-udf")
    fn = lambda a: kern(np.asarray(a))  # noqa: E731
    return LazyColumn(col.frame, E.UDF(fn, (col.expr,), name=f"fallback.dt.{field}"))


# ---------------------------------------------------------------------------
# .str accessor fallback: vocab transforms.  ``len`` is elementwise over a
# per-code lookup table (lazy); casing/strip transforms rebuild the vocab
# eagerly and re-encode.


_STR_TRANSFORMS = {
    "upper": str.upper,
    "lower": str.lower,
    "title": str.title,
    "strip": str.strip,
    "capitalize": str.capitalize,
}


def str_fallback(col, name: str):
    from repro.core.lazyframe import LazyColumn
    try:
        vocab = col.frame._vocab_for(col.expr)
    except KeyError:
        _unsupported(f"Series.str.{name}")

    if name == "len":
        lut = np.asarray([len(v) for v in vocab], np.int64)
        def bound():
            record_fallback("Series.str.len", None, "wrapped-udf")
            fn = lambda a: lut[np.asarray(a)]  # noqa: E731
            return LazyColumn(col.frame, E.UDF(fn, (col.expr,), name="fallback.str.len"))
        return bound

    xform = _STR_TRANSFORMS.get(name)
    if xform is None:
        _unsupported(f"Series.str.{name}")

    def bound():
        codes = _materialize_series(col, f"str.{name}")
        record_fallback(f"Series.str.{name}", (len(codes),), "materialize-input")
        new_codes, new_vocab = encode_strings([xform(vocab[c]) for c in codes])
        return _series_from(new_codes, _series_name(col), f"str.{name}",
                            vocab=new_vocab)

    bound.__name__ = name
    return bound
