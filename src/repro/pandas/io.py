"""Facade I/O: ``pd.read_csv`` / ``read_npz`` / ``read_source`` /
``from_arrays``.

``read_csv`` is a minimal-but-robust CSV reader: numeric columns inferred
(int64, falling back to float64-with-NaN when cells are blank), strings
dictionary-encoded, ISO datetimes → int64 epoch seconds.  ``usecols`` comes
from the user or from JIT static analysis (paper Fig. 4)."""
from __future__ import annotations

import numpy as np

from repro.core import graph as G
from repro.core.context import get_context
from repro.core.lazyframe import LazyFrame, read_source as _read_source
from repro.core.source import InMemorySource, encode_strings
from repro.core.jit_analyze import usecols_hint

# Tokens treated as missing values during inference (case-insensitive).
_NA_TOKENS = frozenset({"", "na", "n/a", "nan", "null", "none"})

# Sentinel epoch for unparseable/blank datetime cells (NaT analogue — int
# columns can't carry NaN).  int32-min so it survives the device path even
# when jax runs with x64 disabled (int64 arrays truncate to int32 there).
NAT_SENTINEL = int(np.iinfo(np.int32).min)


def _is_na(v: str) -> bool:
    return v.strip().lower() in _NA_TOKENS


def _apply_usecols(source, cols):
    """Record static usecols for this source (column selection, §3.1)."""
    ctx = get_context()
    if cols is not None and ctx.analysis:
        ctx.analysis.setdefault("scan_extra_cols", {})[id(source)] = list(cols)
    return source


def _frame_over(source, cols):
    """Lazy frame over ``source``, projected to ``cols`` when given."""
    frame = _read_source(_apply_usecols(source, cols))
    if cols is not None:
        valid = [c for c in cols if c in source.schema]
        if valid:
            frame = LazyFrame(G.Scan(source, tuple(valid)),
                              source_vocab=source.dicts)
    return frame


def read_source(source):
    return _frame_over(source, usecols_hint())


def read_npz(path: str):
    from repro.core.source import NpzDirectorySource
    return read_source(NpzDirectorySource(path))


def read_parquet(path: str, columns=None):
    """Lazy frame over a parquet file or ``part-*.parquet`` directory
    (``repro.io.ParquetSource``): scans are column-pruned and
    predicate-pushed, partitions pruned via the sidecar zone maps.
    Requires pyarrow."""
    from repro.io import ParquetSource
    src = ParquetSource(path)
    cols = columns if columns is not None else usecols_hint()
    return _frame_over(src, cols)


def from_arrays(arrays, partition_rows: int = 1 << 16, dicts=None,
                datetimes=(), name="mem"):
    src = InMemorySource(arrays, partition_rows, dicts, datetimes, name)
    return read_source(src)


def _coerce_numeric(vals) -> np.ndarray | None:
    """int64 when every cell parses as int; float64-with-NaN when cells are
    blank/NA or fractional; None when the column isn't numeric at all."""
    clean = [v for v in vals if not _is_na(v)]
    if not clean:
        return None                        # all-blank: not numeric evidence
    if len(clean) == len(vals):
        try:
            return np.asarray(vals, dtype=np.int64)
        except (ValueError, OverflowError):
            pass
    try:
        return np.asarray([np.nan if _is_na(v) else float(v) for v in vals],
                          dtype=np.float64)
    except (ValueError, OverflowError):
        return None


def _looks_datetime(vals) -> bool:
    """Probe the first *non-blank* value for an ISO date shape."""
    probe = next((v for v in vals if not _is_na(v)), "")
    return len(probe) >= 10 and probe[4:5] == "-" and probe[7:8] == "-"


def _parse_datetimes(vals) -> np.ndarray:
    import datetime as _dt
    out = np.empty(len(vals), np.int64)
    for i, v in enumerate(vals):
        if _is_na(v):
            out[i] = NAT_SENTINEL
            continue
        v = v.strip().replace("T", " ")
        fmt = "%Y-%m-%d %H:%M:%S" if len(v) > 10 else "%Y-%m-%d"
        out[i] = int(_dt.datetime.strptime(v, fmt)
                     .replace(tzinfo=_dt.timezone.utc).timestamp())
    return out


def _parse_csv(path: str, hint, dtype, parse_dates):
    """CSV → (arrays, dicts, datetimes) under the inference rules above."""
    import csv as _csv

    with open(path, newline="") as f:
        reader = _csv.reader(f)
        header = next(reader)
        keep = [i for i, h in enumerate(header)
                if hint is None or h in hint]
        names = [header[i] for i in keep]
        cols: dict[str, list] = {n: [] for n in names}
        for row in reader:
            if not row:
                continue                    # skip blank lines (pandas default)
            for i, n in zip(keep, names):
                cols[n].append(row[i] if i < len(row) else "")
    arrays: dict[str, np.ndarray] = {}
    dicts: dict[str, list] = {}
    datetimes: list[str] = list(parse_dates)
    for n, vals in cols.items():
        if n in datetimes:
            arrays[n] = _parse_datetimes(vals)
            continue
        arr = _coerce_numeric(vals)
        if arr is None:
            if _looks_datetime(vals):
                arrays[n] = _parse_datetimes(vals)
                datetimes.append(n)
                continue
            codes, vocab = encode_strings(vals)
            arrays[n] = codes
            dicts[n] = vocab
            continue
        if dtype and n in dtype:
            arr = arr.astype(dtype[n])
        arrays[n] = arr
    return arrays, dicts, datetimes


def _csv_cache_params(dtype, parse_dates) -> dict:
    """Normalized parse options, part of the parquet cache's identity: the
    cache pins the schema produced by ``(dtype, parse_dates)``, so a later
    call with different options must read as stale, not silently serve the
    first call's schema."""
    return {"dtype": {str(k): np.dtype(v).str
                      for k, v in sorted((dtype or {}).items())},
            "parse_dates": sorted(str(c) for c in parse_dates)}


def _fresh_parquet_cache(cache_path: str, csv_path: str, params: dict):
    """Reopen a ``to_parquet_cache`` directory when its sidecar records the
    CSV's current ``(size, mtime_ns)`` AND the same parse params — else
    ``None`` (rebuild)."""
    import os

    from repro.io import HAS_PYARROW
    if not HAS_PYARROW or not os.path.isdir(cache_path):
        return None
    from repro.io import ParquetSource, parquet_files
    from repro.io import sidecar as SC
    files = parquet_files(cache_path)
    if not files:
        return None
    payload = SC.read_sidecar(cache_path, data_files=files)
    if not payload:
        return None
    ingest = payload.get("ingest") or {}
    try:
        state = SC.file_state(csv_path)
    except OSError:
        return None
    if list(ingest.get(os.path.abspath(csv_path), ())) != state:
        return None
    if ingest.get("__params__") != params:
        return None
    return ParquetSource(cache_path)


def read_csv(path: str, usecols=None, dtype=None, parse_dates=(),
             to_parquet_cache: str | None = None):
    hint = usecols if usecols is not None else usecols_hint()
    if to_parquet_cache is not None:
        # opt-in columnar cache: parse once (ALL columns, so later reads
        # with different projections reuse the same cache), serve every
        # fresh re-open from parquet + sidecar without touching the CSV
        import os

        params = _csv_cache_params(dtype, parse_dates)
        src = _fresh_parquet_cache(to_parquet_cache, path, params)
        if src is None:
            from repro.io import sidecar as SC
            from repro.io.parquet import write_parquet_source
            arrays, dicts, datetimes = _parse_csv(path, None, dtype,
                                                  parse_dates)
            src = write_parquet_source(
                to_parquet_cache, arrays, dicts=dicts, datetimes=datetimes,
                ingest={os.path.abspath(path): SC.file_state(path),
                        "__params__": params})
        return _frame_over(src, hint)
    arrays, dicts, datetimes = _parse_csv(path, hint, dtype, parse_dates)
    src = InMemorySource(arrays, dicts=dicts, datetimes=datetimes,
                         name=path)
    return _read_source(_apply_usecols(src, hint))
