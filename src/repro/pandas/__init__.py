"""`repro.pandas` — the canonical drop-in facade (paper Fig. 2).

A plain-pandas program needs exactly two changed lines:

    import repro.pandas as pd     # ① the import swap
    pd.analyze()                  # ② JIT static analysis

Everything else is pandas-shaped: ``pd.DataFrame`` / ``pd.Series`` /
``pd.read_csv`` / ``pd.concat`` / ``pd.merge`` / ``pd.to_datetime`` /
``pd.isna``, DataFrame methods, ``.dt`` / ``.str`` accessors, groupby.
``analyze()`` additionally rebinds ``print``/``len`` in a ``__main__``
script to their lazy sink-building versions (the paper's program rewrite),
so deferred output needs no third import.

Ops the lazy layer lacks are served by the **measured fallback protocol**
(see `repro.pandas.fallback`): inputs materialize, a numpy-level kernel
runs eagerly, the result re-wraps as a lazy source, and the event lands in
``get_context().fallback_trace``.

Engines are **string-named** and pluggable (``repro.core.engines``):

    pd.BACKEND_ENGINE = "streaming"                   # round-trips
    with pd.session(engine="auto", memory_budget=2**28,
                    engines=("eager", "streaming")):  # AUTO allow-list
        ...isolated planner/persist/sink/stats state...
    pd.register_engine("pool", PoolEngine, capability)  # out-of-tree engine
    print(pd.explain())           # typed report: segments, candidates,
                                  # handoffs, fallbacks, calibration

``BackendEngines`` remains as a deprecated ``str``-mixin enum alias layer
(members compare equal to the plain names).
"""
from __future__ import annotations

import sys
import types

from repro.core.context import (BackendEngines, LaFPContext, default_context,
                                get_context, pop_session, push_session,
                                session)
from repro.core.engines import (BackendCapability, create_engine,
                                engine_names, get_capability,
                                normalize_engine, register_engine,
                                unregister_engine)
from repro.core.explain import ExplainReport, explain
from repro.core.lazyframe import LazyColumn, LazyFrame, Result
from repro.core.jit_analyze import analyze
from repro.core.runtime import flush
from repro.obs import Profile, profile

from .api import DataFrame, Series, concat, isna, merge, notna, to_datetime
from .fallback import FallbackEvent, record_fallback
from .io import from_arrays, read_csv, read_npz, read_parquet, read_source

__all__ = [
    "analyze", "flush", "session", "get_context", "default_context",
    "push_session", "pop_session", "LaFPContext",
    "DataFrame", "Series", "LazyFrame", "LazyColumn", "Result",
    "read_csv", "read_npz", "read_parquet", "read_source", "from_arrays",
    "concat", "merge", "to_datetime", "isna", "notna",
    "BackendEngines", "BACKEND_ENGINE", "set_backend",
    "register_engine", "unregister_engine", "engine_names",
    "get_capability", "create_engine", "BackendCapability",
    "explain", "ExplainReport",
    "FallbackEvent", "record_fallback",
    "profile", "Profile",
]


def set_backend(engine, **options):
    """Switch the current session's engine by name (``"eager"``,
    ``"streaming"``, ``"distributed"``, ``"auto"``, or any registered
    plug-in engine); extra options flow into ``ctx.backend_options``."""
    ctx = get_context()
    ctx.backend = normalize_engine(engine, warn_enum=True)
    ctx.backend_options.update(options)


class _FacadeModule(types.ModuleType):
    """Module subclass making ``pd.BACKEND_ENGINE`` a *live* property: reads
    and writes go to the current session's context, so plain attribute
    assignment (the paper's §2.6 one-liner) actually switches the engine —
    fixing the seed bug where assignment shadowed the module ``__getattr__``
    and silently did nothing.  Accepts string engine names (the redesigned
    API) and, as a deprecated alias, ``BackendEngines`` members; unknown
    names raise with the list of registered engines."""

    @property
    def BACKEND_ENGINE(self) -> str:
        return get_context().backend

    @BACKEND_ENGINE.setter
    def BACKEND_ENGINE(self, value):
        # TypeError on non-str junk; DeprecationWarning on enum members
        name = normalize_engine(value, warn_enum=True)
        if name != "auto":
            get_capability(name)                # ValueError on unknown names
        get_context().backend = name


sys.modules[__name__].__class__ = _FacadeModule
