"""`repro.pandas` — the canonical drop-in facade (paper Fig. 2).

A plain-pandas program needs exactly two changed lines:

    import repro.pandas as pd     # ① the import swap
    pd.analyze()                  # ② JIT static analysis

Everything else is pandas-shaped: ``pd.DataFrame`` / ``pd.Series`` /
``pd.read_csv`` / ``pd.concat`` / ``pd.merge`` / ``pd.to_datetime`` /
``pd.isna``, DataFrame methods, ``.dt`` / ``.str`` accessors, groupby.
``analyze()`` additionally rebinds ``print``/``len`` in a ``__main__``
script to their lazy sink-building versions (the paper's program rewrite),
so deferred output needs no third import.

Ops the lazy layer lacks are served by the **measured fallback protocol**
(see `repro.pandas.fallback`): inputs materialize, a numpy-level kernel
runs eagerly, the result re-wraps as a lazy source, and the event lands in
``get_context().fallback_trace``.

The backend switch is a real module-level property (module-class swap):

    pd.BACKEND_ENGINE = pd.BackendEngines.STREAMING   # round-trips
    with pd.session(backend=pd.BackendEngines.AUTO, memory_budget=2**28):
        ...isolated planner/persist/sink/stats state...
"""
from __future__ import annotations

import sys
import types

from repro.core.context import (BackendEngines, LaFPContext, default_context,
                                get_context, pop_session, push_session,
                                session)
from repro.core.lazyframe import LazyColumn, LazyFrame, Result
from repro.core.runtime import flush
from repro.core.tracer import analyze

from .api import DataFrame, Series, concat, isna, merge, notna, to_datetime
from .fallback import FallbackEvent, record_fallback
from .io import from_arrays, read_csv, read_npz, read_source

__all__ = [
    "analyze", "flush", "session", "get_context", "default_context",
    "push_session", "pop_session", "LaFPContext",
    "DataFrame", "Series", "LazyFrame", "LazyColumn", "Result",
    "read_csv", "read_npz", "read_source", "from_arrays",
    "concat", "merge", "to_datetime", "isna", "notna",
    "BackendEngines", "BACKEND_ENGINE", "set_backend",
    "FallbackEvent", "record_fallback",
]


def set_backend(engine: BackendEngines, **options):
    ctx = get_context()
    ctx.backend = engine
    ctx.backend_options.update(options)


class _FacadeModule(types.ModuleType):
    """Module subclass making ``pd.BACKEND_ENGINE`` a *live* property: reads
    and writes go to the current session's context, so plain attribute
    assignment (the paper's §2.6 one-liner) actually switches the engine —
    fixing the seed bug where assignment shadowed the module ``__getattr__``
    and silently did nothing."""

    @property
    def BACKEND_ENGINE(self) -> BackendEngines:
        return get_context().backend

    @BACKEND_ENGINE.setter
    def BACKEND_ENGINE(self, value: BackendEngines):
        if not isinstance(value, BackendEngines):
            raise TypeError(
                f"BACKEND_ENGINE must be a BackendEngines member, got {value!r}")
        get_context().backend = value


sys.modules[__name__].__class__ = _FacadeModule
