"""Training input pipeline built ON the LaFP engine — this is where the
paper's technique integrates into the trainer (DESIGN §3).

Token shards are a partitioned columnar source (columns: tokens, doc_len,
quality, domain, …).  Filtering / column selection / batching are LazyFrame
ops, so the full LaFP optimizer applies:

* column selection drops unused metadata columns at the read (usecols),
* predicate pushdown + zone-map pruning skip shards that can't contain
  surviving rows (e.g. quality or length filters),
* the streaming backend bounds host memory for larger-than-RAM corpora,
* lazy sinks batch metrics/logging host transfers like lazy print.

The pipeline yields fixed-shape (B, S) token/label batches; a bounded
prefetch thread overlaps host prep with device steps, and the cursor state
(shard index, rng) is checkpointable (fault tolerance — a restarted host
resumes mid-epoch deterministically).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np

from ..core import get_context
from ..core.lazyframe import LazyFrame, read_source
from ..core.source import InMemorySource, Source, write_npz_source


@dataclasses.dataclass
class PipelineConfig:
    batch: int
    seq: int
    min_doc_len: int = 1
    min_quality: float = -1e9
    shuffle: bool = True
    seed: int = 0
    prefetch: int = 2
    backend: str = "streaming"
    drop_remainder: bool = True


@dataclasses.dataclass
class PipelineState:
    """Checkpointable cursor."""
    epoch: int = 0
    batch_index: int = 0
    rng_state: int = 0

    def to_dict(self):
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d):
        return cls(**d)


def synthetic_token_source(n_docs: int, seq: int, vocab: int, seed: int = 0,
                           partition_rows: int = 1024,
                           path: str | None = None) -> Source:
    """Synthetic corpus: packed token rows + metadata columns the filters
    exercise (doc_len, quality, domain)."""
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, vocab, (n_docs, seq), dtype=np.int32)
    arrays = {
        **{f"tok_{i}": tokens[:, i] for i in range(seq)},
        "doc_len": rng.integers(1, seq + 1, n_docs).astype(np.int32),
        "quality": rng.uniform(0, 1, n_docs).astype(np.float32),
        "domain": rng.integers(0, 8, n_docs).astype(np.int32),
    }
    if path is not None:
        return write_npz_source(path, arrays, partition_rows)
    return InMemorySource(arrays, partition_rows, name="synthetic")


class TokenPipeline:
    """LazyFrame-backed batch iterator."""

    def __init__(self, source: Source, cfg: PipelineConfig, seq: int | None = None):
        self.source = source
        self.cfg = cfg
        self.seq = seq or cfg.seq
        self.state = PipelineState(rng_state=cfg.seed)
        self._tok_cols = [c for c in source.schema.names
                          if c.startswith("tok_")][: self.seq]

    def _frame(self) -> LazyFrame:
        df = read_source(self.source)
        if self.cfg.min_doc_len > 1:
            df = df[df["doc_len"] >= self.cfg.min_doc_len]
        if self.cfg.min_quality > -1e9:
            df = df[df["quality"] >= self.cfg.min_quality]
        # column selection: only token columns survive to the device
        return df[self._tok_cols]

    def _materialize_epoch(self) -> np.ndarray:
        ctx = get_context()
        prev = ctx.backend
        ctx.backend = self.cfg.backend
        try:
            res = self._frame().compute()
        finally:
            ctx.backend = prev
        # LaFP dtype narrowing may have narrowed token columns to int8/16;
        # device batches are always int32 (embedding gather index type).
        mat = np.stack([np.asarray(res[c]) for c in self._tok_cols],
                       axis=1).astype(np.int32)
        return mat  # (rows, seq)

    def __iter__(self) -> Iterator[dict]:
        B = self.cfg.batch
        while True:
            mat = self._materialize_epoch()
            n = mat.shape[0]
            order = np.arange(n)
            if self.cfg.shuffle:
                rng = np.random.default_rng(self.cfg.seed + self.state.epoch)
                rng.shuffle(order)
            nb = n // B if self.cfg.drop_remainder else -(-n // B)
            start = self.state.batch_index
            for bi in range(start, nb):
                rows = order[bi * B:(bi + 1) * B]
                toks = mat[rows]
                labels = np.concatenate(
                    [toks[:, 1:], np.full((toks.shape[0], 1), -100,
                                          np.int32)], axis=1)
                self.state.batch_index = bi + 1
                yield {"tokens": toks, "labels": labels}
            self.state.epoch += 1
            self.state.batch_index = 0


class PrefetchIterator:
    """Bounded background prefetch: a slow host degrades prefetch depth
    instead of stalling the device step (straggler mitigation)."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
