"""Serving: prefill + batched decode with static-shape KV caches.

``make_prefill_step``/``make_serve_step`` are the functions the dry-run
lowers for the ``prefill_*`` and ``decode_*``/``long_*`` shapes.  The Engine
class runs real batched generation (smoke-scale on CPU): continuous batching
over a fixed slot grid, per-slot cache lengths, greedy or temperature
sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..models.transformer import forward, init_cache


def make_prefill_step(cfg):
    """prefill(params, inputs) → (last_logits (B,V), cache, cache_len)."""

    def prefill_step(params, inputs):
        logits, cache, _ = forward(params, cfg, inputs, mode="prefill")
        B = logits.shape[0]
        T = logits.shape[1]
        cache_len = jnp.full((B,), T, jnp.int32)
        return logits[:, -1], cache, cache_len

    return prefill_step


def make_serve_step(cfg):
    """decode(params, inputs{tokens/embeds, cache, cache_len}) →
    (logits (B,1,V), new_cache, new_cache_len).  One new token against the
    existing cache — the function lowered for decode_32k / long_500k."""

    def serve_step(params, inputs):
        cache = inputs["cache"]
        cache_len = inputs["cache_len"]
        feed = {k: v for k, v in inputs.items()
                if k not in ("cache", "cache_len")}
        logits, new_cache, _ = forward(params, cfg, feed, mode="decode",
                                       cache=cache, cache_len=cache_len)
        return logits, new_cache, cache_len + 1

    return serve_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: Any                  # (T,) tokens or (T,D) embeds
    max_new: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    """Slot-based continuous batching engine (CPU/smoke scale)."""

    def __init__(self, cfg, params, max_batch: int = 4, max_seq: int = 128,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.B = max_batch
        self.S = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.cache = init_cache(cfg, self.B, self.S,
                                jnp.float32 if cfg.activation_dtype == jnp.float32
                                else jnp.bfloat16)
        self.cache_len = jnp.zeros((self.B,), jnp.int32)
        self.slots: list[Request | None] = [None] * self.B
        self.decode = jax.jit(make_serve_step(cfg))
        self.queue: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.B):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # sequential prefill into slot i (simple; batch-prefill is a
                # straightforward extension)
                prompt = jnp.asarray(req.prompt)
                for t in range(prompt.shape[0]):
                    feed = {"tokens": prompt[None, t:t + 1]} \
                        if self.cfg.modality == "text" else \
                        {"embeds": prompt[None, t:t + 1]}
                    self._step_slot(i, feed)

    def _step_slot(self, slot: int, feed):
        """Single-slot decode via masked batch step (smoke-scale)."""
        full = self._broadcast_feed(feed, slot)
        logits, new_cache, new_len = self.decode(
            self.params, {**full, "cache": self.cache,
                          "cache_len": self.cache_len})
        # only commit the targeted slot's cache rows
        self.cache = jax.tree.map(
            lambda old, new: old.at[slot].set(new[slot]), self.cache,
            new_cache)
        self.cache_len = self.cache_len.at[slot].set(new_len[slot])
        return logits[slot, 0]

    def _broadcast_feed(self, feed, slot):
        out = {}
        for k, v in feed.items():
            full = jnp.zeros((self.B,) + v.shape[1:], v.dtype)
            out[k] = full.at[slot].set(v[0])
        return out

    def _sample(self, logits):
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    def run(self, max_steps: int = 64) -> list[Request]:
        finished = []
        for _ in range(max_steps):
            self._admit()
            active = [i for i, r in enumerate(self.slots) if r is not None]
            if not active and not self.queue:
                break
            for i in active:
                req = self.slots[i]
                last = int(req.out_tokens[-1]) if req.out_tokens else 0
                feed = {"tokens": jnp.asarray([[last]], jnp.int32)} \
                    if self.cfg.modality == "text" else \
                    {"embeds": jnp.zeros((1, 1, self.cfg.d_model),
                                         jnp.float32)}
                logits = self._step_slot(i, feed)
                tok = self._sample(logits)
                req.out_tokens.append(tok)
                if len(req.out_tokens) >= req.max_new or \
                        int(self.cache_len[i]) >= self.S - 1:
                    req.done = True
                    finished.append(req)
                    self.slots[i] = None
                    self.cache_len = self.cache_len.at[i].set(0)
        return finished
